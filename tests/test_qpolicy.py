"""QuantPolicy API: role/depth resolution, from_recipe seed-equivalence,
kernel-backend dispatch + fallback, string codecs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (Granularity, LinearCtx, QuantPolicy, QuantRecipe,
                        QuantSpec, get_recipe, paper_recipe, parse_policy,
                        parse_recipe, quantized_linear)
from repro.core.qlinear import int8_backend_supported
from repro.core.qpolicy import PolicyRule, as_policy
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _xw(m=8, k=32, n=16, batch=(3,)):
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (*batch, m, k))
    w = jax.random.normal(kw, (k, n)) * 0.2
    return x, w


# ---------------------------------------------------------------------------
# recipe string codec
# ---------------------------------------------------------------------------

def test_parse_recipe_roundtrip_presets():
    for name in ("fp", "paper", "paper_wag8", "beyond"):
        r = get_recipe(name)
        assert parse_recipe(r.describe_compact()) == r


def test_parse_recipe_components():
    r = parse_recipe("w8c,a8t,g8t,m1:4c")
    assert r.weights == QuantSpec(8, Granularity.PER_CHANNEL)
    assert r.acts == QuantSpec(8, Granularity.PER_TOKEN)
    assert r.grads == QuantSpec(8, Granularity.PER_TOKEN)
    assert r.adam_m1 == QuantSpec(4, Granularity.PER_CHANNEL)
    # '+' separator (for embedding in policy strings) and flags
    r2 = parse_recipe("w4n+m2:8c-asym-b128-sqrt")
    assert r2.weights == QuantSpec(4, Granularity.PER_TENSOR)
    assert r2.adam_m2 == QuantSpec(8, Granularity.PER_CHANNEL,
                                   symmetric=False, block_size=128,
                                   sqrt_domain=True)
    # get_recipe falls through to the codec
    assert get_recipe("w8c+a8t") == paper_recipe()


def test_parse_recipe_errors():
    with pytest.raises(ValueError):
        parse_recipe("w8q")            # bad granularity code
    with pytest.raises(ValueError):
        parse_recipe("w8c,w4c")        # duplicate component
    with pytest.raises(KeyError):
        get_recipe("not_a_preset_or_spec!")


# ---------------------------------------------------------------------------
# role / depth resolution
# ---------------------------------------------------------------------------

def test_rule_precedence_first_match_wins():
    fp8 = QuantRecipe(weights=QuantSpec(8, Granularity.PER_CHANNEL))
    fp4 = QuantRecipe(weights=QuantSpec(4, Granularity.PER_CHANNEL))
    pol = QuantPolicy(rules=(PolicyRule(role="mlp_up", recipe=fp4),
                             PolicyRule(role="*", recipe=fp8)),
                      default=None)
    assert pol.resolve("mlp_up").recipe == fp4          # specific beats later *
    assert pol.resolve("attn_qkv").recipe == fp8        # wildcard
    # unmatched (no wildcard) falls to the default
    pol2 = QuantPolicy(rules=(PolicyRule(role="embed"),), default=fp8)
    assert pol2.resolve("embed").recipe is None
    assert pol2.resolve("mlp_down").recipe == fp8


def test_depth_indexed_resolution():
    pol = parse_policy("block[0:2].*=fp,block[-1:].*=fp,*=w8c+a8t")
    n = 6
    assert pol.resolve("mlp_up", 0, n).recipe is None
    assert pol.resolve("mlp_up", 1, n).recipe is None
    assert pol.resolve("mlp_up", 2, n).recipe == paper_recipe()
    assert pol.resolve("mlp_up", n - 1, n).recipe is None    # negative index
    # depth-bounded rules never match depth-less call sites
    assert pol.resolve("shared_proj", None, n).recipe == paper_recipe()
    assert pol.depth_sensitive("mlp_up")
    # block[:] stays depth-bounded: catches every block, not embed/lm_head
    every = parse_policy("block[:].*=w4c,*=w8c+a8t")
    assert every.resolve("mlp_up", 0, n).recipe.weights.bits == 4
    assert every.resolve("embed").recipe is None


def test_parse_policy_seeds_paper_scope_exclusions():
    """A bare wildcard quantizes block linears only (from_recipe parity);
    naming a role explicitly -- or 'emb' in the recipe -- lifts it."""
    pol = parse_policy("*=w8c+a8t")
    for role in ("embed", "lm_head", "router", "patch_proj"):
        assert pol.resolve(role).recipe is None, role
    for role in ("attn_qkv", "mlp_down", "ssm_in", "frame_proj",
                 "shared_proj"):
        assert pol.resolve(role).recipe == paper_recipe(), role
    # explicit rule wins over the seeded exclusion
    pol2 = parse_policy("embed=w8c,*=w8c+a8t")
    assert pol2.resolve("embed").recipe is not None
    assert pol2.resolve("lm_head").recipe is None
    # 'emb' flag in the wildcard recipe lifts embed/lm_head (not router)
    pol3 = parse_policy("*=w8c+a8t+emb")
    assert pol3.resolve("embed").recipe is not None
    assert pol3.resolve("lm_head").recipe is not None
    assert pol3.resolve("router").recipe is None


def test_parse_policy_backend_and_describe_roundtrip():
    pol = parse_policy("embed=fp,block[0:2].*=fp,*=w8c+a8t@int8_pallas")
    assert pol.resolve("mlp_up", 3, 4).backend == "int8_pallas"
    assert pol.resolve("mlp_up", 0, 4).recipe is None
    assert pol.adam_m1 is None and pol.default == paper_recipe()
    re_parsed = parse_policy(pol.describe())
    assert re_parsed.describe() == pol.describe()
    with pytest.raises(ValueError):
        parse_policy("not_a_role=w8c")
    with pytest.raises(ValueError):
        parse_policy("*=w8c@no_such_backend")


def test_rules_inherit_policy_backend_regardless_of_order():
    """A role rule placed BEFORE the wildcard (as first-match-wins requires)
    still runs on the wildcard's backend unless it names its own."""
    pol = parse_policy("mlp_down=w8c+a8n,*=w8c+a8t@int8_pallas")
    assert pol.resolve("mlp_down").backend == "int8_pallas"
    assert pol.resolve("mlp_up").backend == "int8_pallas"
    pol2 = parse_policy("mlp_down=w8c+a8n@fake_quant,*=w8c+a8t@int8_pallas")
    assert pol2.resolve("mlp_down").backend == "fake_quant"


def test_moment_specs_outside_default_are_rejected():
    """m1:/m2: only take effect on the depth-less '*' entry; anywhere else
    they would silently run fp moments -- reject loudly instead."""
    with pytest.raises(ValueError, match="optimizer-moment"):
        parse_policy("block[2:10].*=w8c+a8t+m2:8c-b128-sqrt")
    with pytest.raises(ValueError, match="optimizer-moment"):
        parse_policy("mlp_up=w8c+m1:4c,*=w8c+a8t")
    # ...but the wildcard itself carries them fine
    pol = parse_policy("*=w8c+a8t+m1:4c")
    assert pol.adam_m1 is not None


# ---------------------------------------------------------------------------
# from_recipe seed-path equivalence
# ---------------------------------------------------------------------------

def test_from_recipe_linear_bitwise_matches_quantized_linear():
    x, w = _xw()
    r = paper_recipe()
    pol = QuantPolicy.from_recipe(r)
    for role in ("attn_qkv", "attn_out", "mlp_up", "mlp_down", "ssm_in",
                 "ssm_out", "frame_proj", "shared_proj"):
        y = pol.linear(LinearCtx(role, layer=2, n_layers=4), x, w)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(
            quantized_linear(x, w, r)))
    # excluded roles are plain fp matmuls (seed scoping)
    for role in ("embed", "lm_head", "router", "patch_proj"):
        y = pol.linear(LinearCtx(role), x, w)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x @ w))


def test_from_recipe_train_loss_bit_identical_on_smoke_gpt2():
    """model.train_loss(recipe=R) == model.train_loss(policy=from_recipe(R))
    bit-for-bit over train steps (the facade wraps recipes identically)."""
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    recipe = paper_recipe()
    pol = QuantPolicy.from_recipe(recipe)
    opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=6)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                          cfg.vocab_size)}
    s_r = init_train_state(model, KEY, recipe, opt)
    s_p = init_train_state(model, KEY, pol, opt)
    step_r = jax.jit(make_train_step(model, recipe, opt))
    step_p = jax.jit(make_train_step(model, pol, opt))
    for _ in range(3):
        s_r, m_r = step_r(s_r, batch, None)
        s_p, m_p = step_p(s_p, batch, None)
        assert float(m_r["ce"]) == float(m_p["ce"])
    l_r, _ = model.train_loss(s_r.params, batch, recipe=recipe)
    l_p, _ = model.train_loss(s_p.params, batch, policy=pol)
    assert float(l_r) == float(l_p)


def test_fp_policy_is_plain_matmul():
    x, w = _xw()
    for pol in (as_policy(None), QuantPolicy.from_recipe(None),
                as_policy(QuantRecipe())):
        y = pol.linear(LinearCtx("mlp_up", layer=1, n_layers=2), x, w)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x @ w))


# ---------------------------------------------------------------------------
# kernel backends
# ---------------------------------------------------------------------------

def test_int8_backend_matches_fake_quant_reference():
    x, w = _xw(m=64, k=96, n=48, batch=())
    r = paper_recipe()
    assert int8_backend_supported(r)
    pol_int8 = QuantPolicy(default=r, backend="int8_pallas")
    pol_fake = QuantPolicy(default=r)
    ctx = LinearCtx("mlp_up")
    y_i = pol_int8.linear(ctx, x, w)
    y_f = pol_fake.linear(ctx, x, w)
    np.testing.assert_allclose(np.asarray(y_i), np.asarray(y_f),
                               rtol=1e-3, atol=1e-4)
    # backward: identical Fig-1 residual math on both paths
    gi = jax.grad(lambda a: jnp.sum(pol_int8.linear(ctx, a, w) ** 2))(x)
    gf = jax.grad(lambda a: jnp.sum(pol_fake.linear(ctx, a, w) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(gf),
                               rtol=1e-3, atol=1e-3)


def test_int8_backend_falls_back_when_unsupported():
    x, w = _xw()
    # 4-bit weights are outside the int8 kernel contract -> fake_quant path
    r4 = QuantRecipe(weights=QuantSpec(4, Granularity.PER_CHANNEL),
                     acts=QuantSpec(8, Granularity.PER_TOKEN))
    assert not int8_backend_supported(r4)
    pol = QuantPolicy(default=r4, backend="int8_pallas")
    y = pol.linear(LinearCtx("mlp_up"), x, w)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(
        quantized_linear(x, w, r4)))
    # weight-only recipes need the acts quantized too for real-int8 compute
    assert not int8_backend_supported(
        QuantRecipe(weights=QuantSpec(8, Granularity.PER_CHANNEL)))


def test_depth_switch_under_scan_matches_static_resolution():
    """Traced layer index inside lax.scan selects per-layer quantization."""
    x, w = _xw()
    pol = parse_policy("block[0:1].*=fp,*=w8c+a8t")
    n = 3

    def body(carry, li):
        y = pol.linear(LinearCtx("mlp_up", layer=li, n_layers=n), x, w)
        return carry, y

    _, ys = jax.lax.scan(body, None, jnp.arange(n, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(ys[0]), np.asarray(x @ w))
    # scan-compiled branches fuse differently than the eager reference:
    # allow float-ulp noise, but the fp<->quantized gap is orders larger
    want_q = np.asarray(quantized_linear(x, w, paper_recipe()))
    np.testing.assert_allclose(np.asarray(ys[1]), want_q, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ys[2]), want_q, rtol=1e-5,
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(ys[1] - x @ w))) > 1e-3


def test_mixed_policy_smoke_training_with_int8_blocks():
    """Acceptance: fp embed/lm_head + int8_pallas W8A8 blocks trains 20 smoke
    steps without divergence."""
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    pol = parse_policy("embed=fp,lm_head=fp,*=w8c+a8t@int8_pallas")
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=20)
    state = init_train_state(model, KEY, pol, opt)
    step = jax.jit(make_train_step(model, pol, opt))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 65), 0,
                                          cfg.vocab_size)}
    first = None
    for i in range(20):
        state, m = step(state, batch, None)
        ce = float(m["ce"])
        assert np.isfinite(ce) and ce < 30, (i, ce)
        first = first if first is not None else ce
    assert ce < first, (first, ce)       # it actually learns


def test_embed_quantization_via_include_embeddings():
    """include_embeddings routes the table/head through weight qdq; the
    default policy leaves them fp (loss changes only in the former case)."""
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(KEY)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                          cfg.vocab_size)}
    r = paper_recipe()
    import dataclasses
    r_emb = dataclasses.replace(r, include_embeddings=True)
    l_plain, _ = model.train_loss(params, batch, recipe=r)
    l_emb, _ = model.train_loss(params, batch, recipe=r_emb)
    assert float(l_plain) != float(l_emb)
    # 2-bit embed quantization must hurt much more than 8-bit (sanity that
    # the embed role really is quantized, not just perturbed elsewhere)
    r2 = dataclasses.replace(
        r, include_embeddings=True,
        weights=QuantSpec(2, Granularity.PER_CHANNEL))
    l2, _ = model.train_loss(params, batch, recipe=r2)
    assert abs(float(l2) - float(l_plain)) > abs(float(l_emb) - float(l_plain))
