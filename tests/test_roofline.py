"""Roofline infrastructure: loop-aware HLO counting + term computation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.hlo_count import count_module, parse_module
from repro.parallel.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                     parse_collectives, roofline_terms)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _cost(compiled):
    """compiled.cost_analysis() returns a one-element list of dicts on some
    jax releases and a bare dict on others."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_flops_exact_no_loop():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _compile(lambda a, b: jnp.sum(a @ b), x, w)
    counts = count_module(c.as_text(), 1)
    expected = 2 * 128 * 256 * 512
    assert abs(counts["flops"] - expected) / expected < 0.02


def test_flops_loop_multiplied():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, a, None, length=7)
        return jnp.sum(y)

    c = _compile(f, x)
    counts = count_module(c.as_text(), 1)
    expected = 7 * 2 * 64 ** 3
    assert abs(counts["flops"] - expected) / expected < 0.05
    # XLA's own analysis counts the body once -- the bug we work around
    assert _cost(c)["flops"] < expected / 2


def test_nested_loops_multiply():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, a, None, length=5)
        return jnp.sum(y)

    c = _compile(f, x)
    counts = count_module(c.as_text(), 1)
    expected = 15 * 2 * 32 ** 3
    assert abs(counts["flops"] - expected) / expected < 0.1


def test_bytes_match_xla_convention_no_loop():
    x = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((512, 2048), jnp.bfloat16)
    c = _compile(lambda a, b: jnp.sum(jax.nn.gelu(a @ b)), x, w)
    counts = count_module(c.as_text(), 1)
    xla = _cost(c)["bytes accessed"]
    assert abs(counts["bytes"] - xla) / xla < 0.15


def test_parse_module_finds_entry():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    c = _compile(lambda a: a + 1.0, x)
    comps = parse_module(c.as_text())
    assert "__entry__" in comps
    assert len(comps) >= 1


def test_roofline_terms_dominance():
    t = roofline_terms(flops_per_dev=197e12, bytes_per_dev=819e9 * 2,
                       wire_bytes_per_dev=50e9 * 0.5,
                       model_flops_total=197e12 * 256, n_devices=256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 2.0) < 1e-9
    assert abs(t["collective_s"] - 0.5) < 1e-9
    assert t["dominant"] == "memory_s"
    assert abs(t["roofline_mfu"] - 0.5) < 1e-6      # 1s useful / 2s step
    assert abs(t["useful_flops_ratio"] - 1.0) < 1e-6


def test_collective_wire_costs():
    hlo = """
HloModule test
ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %all-reduce = f32[1024]{0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    out = parse_collectives(hlo, 8)
    # 4 KiB fp32, group 4 -> ring all-reduce wire = 2*4096*3/4
    assert abs(out["all-reduce"] - 2 * 4096 * 0.75) < 1.0
    assert out["count"] == 1
