"""AdamW + quantized-state optimizer tests (paper Section 4.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qconfig import Granularity, QuantRecipe, QuantSpec
from repro.optim import (OptConfig, adamw_update, init_adam_state,
                         lr_schedule)

KEY = jax.random.PRNGKey(5)


def _params():
    k1, k2 = jax.random.split(KEY)
    return {"w": jax.random.normal(k1, (64, 128)),
            "b": jax.random.normal(k2, (128,))}


def test_adamw_matches_manual_reference():
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**6,
                    weight_decay=0.0, grad_clip=1e9)
    params = _params()
    grads = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.1, params)
    state = init_adam_state(params, None, cfg)
    new_p, new_s, _ = adamw_update(params, grads, state, cfg, None)

    # manual single step: m=0.01g-ish, v=..., update = m_hat/(sqrt(v_hat)+eps)
    g = 0.1
    m = 0.1 * g
    v = 0.05 * g * g
    mhat, vhat = m / 0.1, v / 0.05
    upd = mhat / (np.sqrt(vhat) + cfg.eps)
    want = np.asarray(params["w"]) - cfg.lr * upd
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_s.step) == 1


def test_weight_decay_only_on_matrices():
    cfg = OptConfig(lr=1e-2, warmup_steps=0, weight_decay=0.1,
                    total_steps=10**6, grad_clip=1e9)
    params = _params()
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    state = init_adam_state(params, None, cfg)
    new_p, _, _ = adamw_update(params, grads, state, cfg, None)
    # zero grads: 2D decays toward zero, 1D untouched
    assert float(jnp.max(jnp.abs(new_p["w"]))) < \
        float(jnp.max(jnp.abs(params["w"])))
    np.testing.assert_allclose(np.asarray(new_p["b"]),
                               np.asarray(params["b"]), rtol=1e-6)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.int32(s), cfg)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] < 1e-3                    # decayed to ~0
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:-1], lrs[2:]))


@pytest.mark.parametrize("storage", ["fake", "int"])
def test_quantized_m1_close_to_fp(storage):
    """8-bit per-channel m1 tracks the fp optimizer closely (paper Fig. 11)."""
    recipe = QuantRecipe(adam_m1=QuantSpec(8, Granularity.PER_CHANNEL))
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**6,
                    weight_decay=0.0, grad_clip=1e9, state_storage=storage)
    params = _params()
    state_q = init_adam_state(params, recipe, cfg)
    state_f = init_adam_state(params, None, cfg)
    p_q, p_f = params, params
    for i in range(5):
        g = jax.tree_util.tree_map(
            lambda p: jax.random.normal(jax.random.fold_in(KEY, i), p.shape)
            * 0.1, params)
        p_q, state_q, _ = adamw_update(p_q, g, state_q, cfg, recipe)
        p_f, state_f, _ = adamw_update(p_f, g, state_f, cfg, None)
    diff = float(jnp.max(jnp.abs(p_q["w"] - p_f["w"])))
    scale = float(jnp.max(jnp.abs(params["w"] - p_f["w"])))
    assert diff < 0.1 * scale, (diff, scale)


def test_m2_linear_quant_zero_bin_vs_blockwise_fix():
    """Paper Fig. 12: symmetric linear m2 quantization collapses small values
    to the zero bin; the beyond-paper sqrt-domain blockwise codec does not."""
    from repro.core.diagnostics import zero_bin_fraction
    from repro.core import qadam
    m2 = jnp.abs(jax.random.normal(KEY, (128, 256))) ** 2 * 1e-4
    plain = QuantSpec(8, Granularity.PER_CHANNEL)
    fixed = QuantSpec(8, Granularity.PER_CHANNEL, symmetric=False,
                      block_size=128, sqrt_domain=True)
    zb_plain = float(zero_bin_fraction(m2, plain))
    enc = qadam.encode(m2, fixed, "int")
    dec = qadam.decode(enc, fixed, m2.shape)
    zb_fixed = float(jnp.mean((dec == 0).astype(jnp.float32)))
    assert zb_plain > 5 * zb_fixed, (zb_plain, zb_fixed)


def test_grad_clip():
    from repro.optim import clip_by_global_norm, global_norm
    tree = {"a": jnp.ones((10,)) * 100.0}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(gn) - 100.0 * np.sqrt(10)) < 1e-2
