"""Flash-attention Pallas kernel: interpret-mode sweeps vs oracle (fwd+bwd)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import (_ref_attend, flash_attention,
                                      flash_attention_fwd, hbm_traffic_bytes)

KEY = jax.random.PRNGKey(0)


def _qkv(bh, sq, skv, d, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(KEY, 3)
    return (jax.random.normal(kq, (bh, sq, d), dtype),
            jax.random.normal(kk, (bh, skv, d), dtype),
            jax.random.normal(kv, (bh, skv, d), dtype))


@pytest.mark.parametrize("bh,sq,skv,d,causal", [
    (4, 128, 128, 64, True), (2, 256, 256, 32, True),
    (2, 128, 256, 64, True), (3, 64, 64, 128, False),
    (1, 100, 100, 64, True), (2, 192, 192, 64, True),
])
def test_forward_sweep(bh, sq, skv, d, causal):
    q, k, v = _qkv(bh, sq, skv, d)
    off = skv - sq if causal else 0
    got = flash_attention_fwd(q, k, v, causal=causal, q_offset=off,
                              block_q=64, block_k=64)
    want = _ref_attend(q, k, v, causal, off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_forward_bf16():
    q, k, v = _qkv(2, 128, 128, 64, jnp.bfloat16)
    got = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64)
    want = _ref_attend(q, k, v, True, 0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("causal", [True, False])
def test_backward_all_grads(causal):
    q, k, v = _qkv(2, 64, 64, 32)

    def loss_fa(a, b, c):
        return jnp.sum(flash_attention(a, b, c, causal, 0, 64, 64, True) ** 2)

    def loss_rf(a, b, c):
        return jnp.sum(_ref_attend(a, b, c, causal, 0) ** 2)

    g_fa = jax.grad(loss_fa, argnums=(0, 1, 2))(q, k, v)
    g_rf = jax.grad(loss_rf, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_fa, g_rf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_hbm_traffic_claim_far_below_materialized():
    """The kernel's DMA schedule vs materializing the score matrix."""
    bh, s, d = 32, 4096, 128
    flash = hbm_traffic_bytes(bh, s, s, d, dtype_bytes=2, block_q=1024)
    materialized = bh * s * s * 4 * 4     # >= 4 fp32 passes over (S,S)
    assert flash < materialized / 20, (flash, materialized)   # measured 25.6x


def test_model_integration_matches_xla_path():
    """Full model forward+grad with attention_impl=flash_pallas vs xla."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("llama3-8b")
    cfg_flash = dataclasses.replace(cfg, attention_impl="flash_pallas")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 65), 0,
                              cfg.vocab_size)
    m1, m2 = build_model(cfg), build_model(cfg_flash)
    p = m1.init_params(jax.random.PRNGKey(0))
    l1, _ = m1.train_loss(p, {"tokens": toks})
    l2, _ = m2.train_loss(p, {"tokens": toks})
    assert abs(float(l1) - float(l2)) < 0.02
    g = jax.grad(lambda pp: m2.train_loss(pp, {"tokens": toks})[0])(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
