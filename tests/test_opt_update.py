"""Fused 8-bit AdamW kernel vs the reference decode->update->encode loop
(kernels/opt_update.py + the bucketed path in optim/adamw.py)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qadam
from repro.core.qconfig import (Granularity, QuantRecipe, QuantSpec,
                                parse_recipe)
from repro.optim import (OptConfig, adamw_update, fused_adam_enabled,
                         init_adam_state, opt_path_desc)

KEY = jax.random.PRNGKey(3)
#: Both moments blockwise (the fused contract); m2 is the beyond-paper
#: asymmetric sqrt-domain codec, so the kernel's asym + sqrt branches run.
RECIPE = parse_recipe("m1:8c-b128,m2:8c-asym-b128-sqrt")


def _params():
    return {
        "w_ragged": jax.random.normal(KEY, (130, 70)),       # 9100 % 128 != 0
        "w_aligned": jax.random.normal(jax.random.fold_in(KEY, 1), (64, 128)),
        "bias": jax.random.normal(jax.random.fold_in(KEY, 2), (128,)),
        "tiny": jax.random.normal(jax.random.fold_in(KEY, 3), (8, 8)),
    }


def _grads(params, i):
    return jax.tree_util.tree_map(
        lambda p: 0.1 * jax.random.normal(jax.random.fold_in(KEY, 100 + i),
                                          p.shape), params)


def _run(monkeypatch, fused: bool, storage: str = "int", steps: int = 3,
         recipe=RECIPE, tile: str = "8"):
    monkeypatch.setenv("REPRO_FUSED_ADAM", "1" if fused else "0")
    monkeypatch.setenv("REPRO_OPT_BLOCK", tile)
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**6,
                    weight_decay=0.1, grad_clip=1.0, state_storage=storage)
    p = _params()
    st = init_adam_state(p, recipe, cfg)
    stats = {}
    for i in range(steps):
        p, st, stats = adamw_update(p, _grads(p, i), st, cfg, recipe)
    return p, st, stats


def test_fused_matches_loop_int_storage(monkeypatch):
    """Parity contract: payloads within one codec bin (fp fusion/FMA ulps can
    flip a round at a bin boundary), scales/zeros to float rounding, params
    well inside one lr of the reference trajectory."""
    p_l, st_l, stats_l = _run(monkeypatch, fused=False)
    p_f, st_f, stats_f = _run(monkeypatch, fused=True)
    for name in p_l:
        dp = float(jnp.max(jnp.abs(p_l[name] - p_f[name])))
        assert dp < 1e-3, (name, dp)                    # lr=1e-2 >> drift
    for tree_l, tree_f in ((st_l.m1, st_f.m1), (st_l.m2, st_f.m2)):
        for name in ("w_ragged", "w_aligned"):
            ml, mf = tree_l[name], tree_f[name]
            assert isinstance(ml, qadam.QState) and isinstance(mf, qadam.QState)
            dq = int(jnp.max(jnp.abs(ml.q.astype(jnp.int32)
                                     - mf.q.astype(jnp.int32))))
            assert dq <= 1, (name, dq)
            np.testing.assert_allclose(np.asarray(ml.scale),
                                       np.asarray(mf.scale), rtol=1e-5)
            assert int(jnp.max(jnp.abs(ml.zero - mf.zero))) <= 1, name
        # non-quantizable leaves take the loop on both sides: bit-identical
        np.testing.assert_array_equal(np.asarray(tree_l["bias"]),
                                      np.asarray(tree_f["bias"]))
        np.testing.assert_array_equal(np.asarray(tree_l["tiny"]),
                                      np.asarray(tree_f["tiny"]))
    np.testing.assert_allclose(float(stats_l["update_norm"]),
                               float(stats_f["update_norm"]), rtol=1e-3)


def test_moment_bytes_and_layout_unchanged(monkeypatch):
    """The fused path must not change what is stored: same QState shapes
    (the blockwise codec layout) and the same byte count as the loop."""
    _, st_l, _ = _run(monkeypatch, fused=False, steps=1)
    _, st_f, _ = _run(monkeypatch, fused=True, steps=1)

    def total(tree):
        return sum(qadam.state_nbytes(l) for l in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, qadam.QState)))

    assert total(st_f.m1) == total(st_l.m1)
    assert total(st_f.m2) == total(st_l.m2)
    for name in ("w_ragged", "w_aligned"):
        q_shape, s_shape = qadam.blockwise_state_shapes(
            _params()[name].shape, RECIPE.adam_m1)
        assert st_f.m1[name].q.shape == q_shape
        assert st_f.m1[name].scale.shape == s_shape
        assert st_f.m1[name].q.dtype == jnp.int8


@pytest.mark.parametrize("storage", ["fake", "fp"])
def test_auto_fallback_for_non_int_storage(monkeypatch, storage):
    """REPRO_FUSED_ADAM=1 with fp/fake storage must fall back to the loop
    bit-for-bit (there are no int payloads to stream)."""
    recipe = None if storage == "fp" else RECIPE
    st_storage = "fake"
    p_l, st_l, _ = _run(monkeypatch, fused=False, storage=st_storage,
                        recipe=recipe, steps=2)
    p_f, st_f, _ = _run(monkeypatch, fused=True, storage=st_storage,
                        recipe=recipe, steps=2)
    for name in p_l:
        np.testing.assert_array_equal(np.asarray(p_l[name]),
                                      np.asarray(p_f[name]))


def test_ragged_bucket_padding_is_safe(monkeypatch):
    """Bucket rows are padded to the kernel tile with 0 payloads and 0
    scales; the encode guard (maximum(.., 1e-12)) must keep every output
    finite -- no 0/0 from padding lanes -- and tail-padded leaves must
    round-trip exactly like the loop."""
    monkeypatch.setenv("REPRO_FUSED_ADAM", "1")
    # tile of 16 rows over 72+64=136 blocks -> 8 fully-padded bucket rows
    monkeypatch.setenv("REPRO_OPT_BLOCK", "16")
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**6,
                    state_storage="int")
    p = {"w": jax.random.normal(KEY, (130, 70)),
         "w2": jax.random.normal(jax.random.fold_in(KEY, 7), (64, 128))}
    st = init_adam_state(p, RECIPE, cfg)
    p2, st2, stats = adamw_update(p, _grads(p, 0), st, cfg, RECIPE)
    for leaf in jax.tree_util.tree_leaves((p2, st2)):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert np.isfinite(float(stats["update_norm"]))
    # fresh scales stay nonzero (guarded), decodable without NaN
    assert float(jnp.min(st2.m1["w"].scale)) > 0.0
    m1 = qadam.decode(st2.m1["w"], RECIPE.adam_m1, p["w"].shape)
    assert np.isfinite(np.asarray(m1)).all()


def test_tile_size_does_not_change_results(monkeypatch):
    """REPRO_OPT_BLOCK only partitions rows across grid steps; every scale
    reduction is per-row, so results are invariant to the tile choice."""
    _, st_a, _ = _run(monkeypatch, fused=True, steps=2, tile="8")
    _, st_b, _ = _run(monkeypatch, fused=True, steps=2, tile="32")
    np.testing.assert_array_equal(np.asarray(st_a.m1["w_ragged"].q),
                                  np.asarray(st_b.m1["w_ragged"].q))
    np.testing.assert_array_equal(np.asarray(st_a.m2["w_aligned"].q),
                                  np.asarray(st_b.m2["w_aligned"].q))


def test_update_norm_is_real(monkeypatch):
    """The update_norm stat (hardcoded 0 before this PR) equals the l2 norm
    of the applied parameter deltas on both paths."""
    for fused in (False, True):
        monkeypatch.setenv("REPRO_FUSED_ADAM", "1" if fused else "0")
        monkeypatch.setenv("REPRO_OPT_BLOCK", "8")
        cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**6,
                        weight_decay=0.0, grad_clip=1e9, state_storage="int")
        p = _params()
        st = init_adam_state(p, RECIPE, cfg)
        p2, _, stats = adamw_update(p, _grads(p, 0), st, cfg, RECIPE)
        want = jnp.sqrt(sum(
            jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
            for a, b in zip(jax.tree_util.tree_leaves(p2),
                            jax.tree_util.tree_leaves(p))))
        np.testing.assert_allclose(float(stats["update_norm"]), float(want),
                                   rtol=1e-4)
        assert float(stats["update_norm"]) > 0.0


def test_eligibility_and_path_desc():
    blk = QuantSpec(8, Granularity.PER_CHANNEL, block_size=128)
    assert qadam.fused_spec_eligible(blk)
    assert not qadam.fused_spec_eligible(None)
    assert not qadam.fused_spec_eligible(
        QuantSpec(8, Granularity.PER_CHANNEL))               # no blocking
    assert not qadam.fused_spec_eligible(
        QuantSpec(16, Granularity.PER_CHANNEL, block_size=128))  # int16
    from repro.core.qconfig import RoundMode
    assert not qadam.fused_spec_eligible(
        QuantSpec(8, Granularity.PER_CHANNEL, block_size=128,
                  round_mode=RoundMode.STOCHASTIC))
    assert qadam.fused_pair_eligible(RECIPE.adam_m1, RECIPE.adam_m2)
    assert not qadam.fused_pair_eligible(
        blk, QuantSpec(8, Granularity.PER_CHANNEL, block_size=64))  # mixed bs

    cfg_int = OptConfig(state_storage="int")
    cfg_fake = OptConfig(state_storage="fake")
    os.environ["REPRO_FUSED_ADAM"] = "1"
    try:
        assert opt_path_desc(RECIPE, cfg_int) == "int8-fused(b128)"
        assert opt_path_desc(RECIPE, cfg_fake) == "fake-loop"
        assert opt_path_desc(None, cfg_int) == "fp-loop"
        assert opt_path_desc(
            QuantRecipe(adam_m1=QuantSpec(8, Granularity.PER_CHANNEL)),
            cfg_int) == "int8-loop"
        assert fused_adam_enabled()
        os.environ["REPRO_FUSED_ADAM"] = "0"
        assert opt_path_desc(RECIPE, cfg_int) == "int8-loop"
    finally:
        os.environ.pop("REPRO_FUSED_ADAM", None)


def test_train_path_summary_opt_segment():
    from repro.train.step import train_path_summary
    cfg = OptConfig(state_storage="int")
    os.environ["REPRO_FUSED_ADAM"] = "1"
    try:
        s = train_path_summary("*=w8c+a8t,m1:8c-b128,m2:8c-asym-b128-sqrt"
                               .replace(",", "+"), opt_cfg=cfg)
    finally:
        os.environ.pop("REPRO_FUSED_ADAM", None)
    assert "opt=int8-fused(b128)" in s
    assert "opt=" not in train_path_summary(None)


def test_state_shardings_bucketed_layout(monkeypatch):
    """QState moments get leading-block-dim shardings (payload AND sidecars)
    instead of blanket replication."""
    from jax.sharding import NamedSharding
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.parallel.sharding import make_rules
    from repro.train.step import init_train_state, state_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, "train")
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    opt = OptConfig(state_storage="int")
    state = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0),
                                 "*=w8c+a8t+m1:8c-b128+m2:8c-b128", opt))
    sh = state_shardings(rules, model, state)
    m1_leaves = [l for l in jax.tree_util.tree_leaves(
        sh.opt.m1, is_leaf=lambda x: isinstance(x, qadam.QState))
        if isinstance(l, qadam.QState)]
    assert m1_leaves, "expected QState moments under the int recipe"
    for qs in m1_leaves:
        assert isinstance(qs.q, NamedSharding)
        assert isinstance(qs.scale, NamedSharding)


def test_loss_curve_smoke_fused_vs_loop(monkeypatch):
    """20 training steps of the gpt2-small smoke config with int8-stored
    moments: the fused kernel tracks the reference loop's loss curve."""
    from repro.configs import get_smoke_config
    from repro.data import Loader, SyntheticCorpus
    from repro.models import build_model
    from repro.train import init_train_state, make_train_step

    def train(fused):
        monkeypatch.setenv("REPRO_FUSED_ADAM", "1" if fused else "0")
        monkeypatch.setenv("REPRO_OPT_BLOCK", "64")
        cfg = get_smoke_config("gpt2-small")
        model = build_model(cfg)
        corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
        loader = Loader(corpus, cfg, batch_size=2, seq_len=32)
        policy = "*=w8c+a8t+m1:8c-b128+m2:8c-asym-b128-sqrt"
        opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=20,
                        state_storage="int")
        state = init_train_state(model, jax.random.PRNGKey(0), policy, opt)
        step = jax.jit(make_train_step(model, policy, opt))
        ces = []
        for i, batch in zip(range(20), loader):
            state, m = step(state, batch, None)
            ces.append(float(m["ce"]))
        return ces

    ce_loop = train(False)
    ce_fused = train(True)
    assert all(np.isfinite(ce_fused)), ce_fused
    assert ce_fused[-1] < ce_fused[0], ce_fused        # it actually learns
    # same trajectory up to codec-ulp drift
    assert abs(ce_fused[-1] - ce_loop[-1]) < 0.05 * abs(ce_loop[-1]), \
        (ce_loop[-1], ce_fused[-1])
