"""Shared test fixtures.

``hypothesis`` is an optional dev dependency (requirements-dev.txt): when it
is absent, only the property-based tests skip -- the deterministic tests in
the same modules still run (a plain ``pytest.importorskip`` at module level
would throw those away too).

``forced8_run`` runs a source snippet in a child interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``: multi-device tests
(sharded serving, distributed train) need a mesh, but forcing host devices
must not leak into the main pytest process, which every other test expects
to hold exactly one real CPU device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def forced8_run():
    """snippet -> stdout, executed under an 8-device forced host platform."""

    def run(snippet: str, timeout: int = 420, extra_env=None) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(_REPO, "src")
        env.update(extra_env or {})
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                             capture_output=True, text=True, timeout=timeout,
                             env=env)
        assert out.returncode == 0, out.stderr[-4000:]
        return out.stdout

    return run

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs ``st.integers(...)``-style calls at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
