"""Shared test fixtures.

``hypothesis`` is an optional dev dependency (requirements-dev.txt): when it
is absent, only the property-based tests skip -- the deterministic tests in
the same modules still run (a plain ``pytest.importorskip`` at module level
would throw those away too).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs ``st.integers(...)``-style calls at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
